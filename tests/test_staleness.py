"""Staleness intelligence (ISSUE 10): age-weighted SED η, the stale-row
forecaster, and the true-age accounting underneath them.

Contract under test:
  * λ = 0 is BIT-exact to the historical Eq.-1 step — passing
    ``sed_decay=0.0`` (or ages with decay 0 at the kernel layer) traces
    the identical jaxpr at every layer: sed_eta, sed_pool dispatch,
    make_train_step, and the dist step (whose age-lookup collective is
    only injected when decay > 0)
  * λ > 0: the aged Pallas kernel matches the jnp oracle (forward + VJP),
    and the dist step with its exchange-routed ``lookup_ages`` matches
    the single-device oracle for every exchange strategy
  * RowForecaster round-trips: age-0 and never-observed rows are the
    identity; a TieredStore with the flag on but no step hints stays
    byte-identical to one with it off
  * TRUE ages: ``refresh_ages`` re-reports device-plane ages so a row
    refreshed while resident stops scoring as its stale fault-in copy —
    the freshly-refreshed row must NOT be the stale-first victim
  * StalenessProbe publishes ``staleness.effective_age`` only when a
    knob is on, and its quantiles sit strictly below raw row-age
    (age·e^{-λ·age} < age pointwise ⇒ every order statistic shrinks)

Runs at whatever device count the host exposes (tier-1: 1 device,
bitwise parity); CI dist-smoke re-runs under
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import dist as DT
from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.dist import exchange as EXC
from repro.dist import pipeline as DP
from repro.dist import table as dtbl
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.kernels import ref
from repro.kernels.sed_pool import sed_pool
from repro.obs.metrics import MetricsRegistry
from repro.obs.staleness import StalenessProbe
from repro.optim import make_optimizer
from repro.store import TieredStore
from repro.store.forecast import RowForecaster
from repro.store.slots import SlotMap

N_DEV = jax.device_count()
SHARD_COUNTS = [d for d in (1, 2, 4, 8) if d <= N_DEV]
HID = 8
HSET = settings(max_examples=8, deadline=None)


def _tree_max_diff(a, b):
    diffs = jax.tree_util.tree_map(
        lambda x, y: float(np.max(np.abs(np.asarray(x) - np.asarray(y)))), a, b)
    return max(jax.tree_util.tree_leaves(diffs), default=0.0)


def _tree_bitwise(a, b):
    eq = jax.tree_util.tree_map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)
    return all(jax.tree_util.tree_leaves(eq))


@pytest.fixture(scope="module")
def dataset():
    graphs = D.make_malnet_like(n_graphs=16, seed=0)
    ds, spec = DP.segment_dataset_shared(graphs, 16, seed=0)
    return ds


def _state(ds, head_out=5):
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, head_out, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    return enc, opt, G.TrainState(bb, head, opt.init((bb, head)),
                                  init_table(ds.n, ds.j_max, HID),
                                  jnp.zeros((), jnp.int32))


def _batch(ds, ids):
    return jax.tree_util.tree_map(jnp.asarray, DP._assemble(ds, ids))


def _aged_draw(B, J, d, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(B, J, d)), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=(B, J)) < 0.8, jnp.float32)
    valid = valid.at[:, 0].set(1.0)
    fresh = jnp.zeros((B, J)).at[jnp.arange(B), rng.integers(0, J, B)].set(1.0)
    fresh = fresh * valid
    drop = jnp.asarray(rng.uniform(size=(B, J)) < 0.5, jnp.float32)
    ages = jnp.asarray(rng.integers(0, 25, (B, J)), jnp.float32)
    return h, valid, fresh, drop, ages


# ---------------------------------------------------------------------------
# sed_eta: the aged Eq.-1 formula and its λ=0 reduction
# ---------------------------------------------------------------------------


def test_sed_eta_decay_zero_is_bitwise_unaged():
    h, valid, fresh, drop, ages = _aged_draw(6, 9, 4, 0)
    base, ji1 = ref.sed_eta(valid, fresh, drop, 0.5, 2)
    aged, ji2 = ref.sed_eta(valid, fresh, drop, 0.5, 2, ages=ages, decay=0.0)
    assert (np.asarray(base) == np.asarray(aged)).all()
    assert (np.asarray(ji1) == np.asarray(ji2)).all()


def test_sed_eta_aged_formula_decays_stale_branch_only():
    h, valid, fresh, drop, ages = _aged_draw(6, 9, 4, 1)
    lam = 0.3
    base = np.asarray(ref.sed_eta(valid, fresh, drop, 0.5, 2)[0])
    aged = np.asarray(ref.sed_eta(valid, fresh, drop, 0.5, 2,
                                  ages=ages, decay=lam)[0])
    f = np.asarray(fresh) > 0
    # fresh branch untouched; stale branch scaled by exp(-λ·age)
    np.testing.assert_array_equal(aged[f], base[f])
    np.testing.assert_allclose(
        aged[~f], base[~f] * np.exp(-lam * np.asarray(ages))[~f],
        rtol=1e-6, atol=1e-7)
    # decay strictly shrinks any live stale weight with nonzero age
    live = (~f) & (base > 0) & (np.asarray(ages) > 0)
    assert live.any() and (aged[live] < base[live]).all()


# ---------------------------------------------------------------------------
# aged sed_pool kernel vs oracle vs VJP
# ---------------------------------------------------------------------------


@given(B=st.integers(1, 12), J=st.integers(1, 16),
       d=st.sampled_from([8, 64, 130]),
       lam=st.sampled_from([0.05, 0.2, 0.5]),
       S=st.integers(1, 3), agg=st.sampled_from(["mean", "sum"]),
       seed=st.integers(0, 10_000))
@HSET
def test_sed_pool_aged_matches_oracle(B, J, d, lam, S, agg, seed):
    S = min(S, J)
    h, valid, fresh, drop, ages = _aged_draw(B, J, d, seed)
    out = sed_pool(h, valid, fresh, drop, keep_prob=0.4, num_sampled=S,
                   agg=agg, ages=ages, decay=lam, interpret=True)
    want = ref.sed_pool_ref(h, valid, fresh, drop, 0.4, S, agg,
                            ages=ages, decay=lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 10_000), agg=st.sampled_from(["mean", "sum"]))
@HSET
def test_sed_pool_aged_vjp_matches_oracle(seed, agg):
    B, J, d, lam, S = 5, 7, 16, 0.2, 2
    h, valid, fresh, drop, ages = _aged_draw(B, J, d, seed)

    def k_loss(x):
        return sed_pool(x, valid, fresh, drop, keep_prob=0.4, num_sampled=S,
                        agg=agg, ages=ages, decay=lam, interpret=True).sum()

    def o_loss(x):
        return ref.sed_pool_ref(x, valid, fresh, drop, 0.4, S, agg,
                                ages=ages, decay=lam).sum()

    np.testing.assert_allclose(np.asarray(jax.grad(k_loss)(h)),
                               np.asarray(jax.grad(o_loss)(h)),
                               rtol=1e-5, atol=1e-5)


def test_sed_pool_decay_zero_dispatches_to_unaged_kernel():
    """ages + decay=0 must route through the historical kernel (same
    jaxpr, bit-identical output) — the λ=0 reduction at the kernel layer."""
    h, valid, fresh, drop, ages = _aged_draw(6, 9, 8, 3)
    base = sed_pool(h, valid, fresh, drop, keep_prob=0.5, num_sampled=1,
                    interpret=True)
    gated = sed_pool(h, valid, fresh, drop, keep_prob=0.5, num_sampled=1,
                     ages=ages, decay=0.0, interpret=True)
    assert (np.asarray(base) == np.asarray(gated)).all()


# ---------------------------------------------------------------------------
# λ=0 bit-exactness through the full train step — all 7 variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_decay_zero_train_step_bit_exact(dataset, variant):
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    rng = jax.random.PRNGKey(3)
    var = G.VARIANTS[variant]
    base = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5))
    zero = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5,
                                     sed_decay=0.0))
    s1, s2 = state0, state0
    for _ in range(3):
        s1, m1 = base(s1, batch, rng)
        s2, m2 = zero(s2, batch, rng)
    assert _tree_bitwise(s1, s2)
    assert float(m1["loss"]) == float(m2["loss"])


@pytest.mark.parametrize("variant", ["gst_ed", "gst_efd"])
def test_decay_zero_pallas_train_step_bit_exact(dataset, variant):
    ds = dataset
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID,
                    use_pallas=True)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    state0 = G.TrainState(bb, head, opt.init((bb, head)),
                          init_table(ds.n, ds.j_max, HID),
                          jnp.zeros((), jnp.int32))
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    rng = jax.random.PRNGKey(3)
    var = G.VARIANTS[variant]
    base = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5,
                                     use_pallas=True))
    zero = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5,
                                     use_pallas=True, sed_decay=0.0))
    s1, _ = base(state0, batch, rng)
    s2, _ = zero(state0, batch, rng)
    assert _tree_bitwise(s1, s2)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_decay_zero_dist_step_bit_exact(dataset, n_shards):
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    rng = jax.random.PRNGKey(3)
    var = G.VARIANTS["gst_efd"]
    ctx = DT.make_context(DT.make_dist_mesh(n_shards), ds.n)
    base = DT.make_dist_train_step(enc, opt, var, ctx=ctx, keep_prob=0.5,
                                   donate=False)
    zero = DT.make_dist_train_step(enc, opt, var, ctx=ctx, keep_prob=0.5,
                                   donate=False, sed_decay=0.0)
    b = DT.shard_batch(ctx, batch)
    s1 = DT.device_state(ctx, state0)
    s2 = DT.device_state(ctx, state0)
    for _ in range(3):
        s1, m1 = base(s1, b, rng)
        s2, m2 = zero(s2, b, rng)
    assert _tree_bitwise(DT.host_table(ctx, s1.table),
                         DT.host_table(ctx, s2.table))
    assert _tree_bitwise(jax.device_get((s1.backbone, s1.head)),
                         jax.device_get((s2.backbone, s2.head)))
    assert float(m1["loss"]) == float(m2["loss"])


# ---------------------------------------------------------------------------
# λ>0: dist step (exchange-routed age lookup) vs single-device oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("exchange", ["ring", "alltoall", "bucketed"])
@pytest.mark.parametrize("variant", ["gst_ed", "gst_efd"])
def test_aged_dist_step_matches_oracle(dataset, variant, exchange):
    ds = dataset
    n_shards = SHARD_COUNTS[-1]
    enc, opt, state0 = _state(ds)
    ids = DP.epoch_ids(ds, 8, rng=np.random.default_rng(0), shuffle=False)[0]
    batch = _batch(ds, ids)
    rng = jax.random.PRNGKey(3)
    var = G.VARIANTS[variant]
    lam = 0.2

    oracle = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5,
                                       sed_decay=lam))
    s1 = state0
    for _ in range(5):
        s1, m1 = oracle(s1, batch, rng)

    cap = None
    if exchange == "bucketed":
        cap = EXC.plan_capacity([ids], num_shards=n_shards,
                                rows=dtbl.rows_per_shard(ds.n, n_shards))
    ctx = DT.make_context(DT.make_dist_mesh(n_shards), ds.n,
                          exchange=exchange, exchange_cap=cap)
    dstep = DT.make_dist_train_step(enc, opt, var, ctx=ctx, keep_prob=0.5,
                                    donate=False, sed_decay=lam)
    s2 = DT.device_state(ctx, state0)
    b2 = DT.shard_batch(ctx, batch)
    for _ in range(5):
        s2, m2 = dstep(s2, b2, rng)

    t2 = DT.host_table(ctx, s2.table)
    # age bookkeeping is pure row selection — bit-exact at any shard count
    assert (np.asarray(s1.table.age) == np.asarray(t2.age)).all()
    assert (np.asarray(s1.table.initialized) ==
            np.asarray(t2.initialized)).all()
    tol = 0.0 if ctx.num_shards == 1 else 1e-5
    assert _tree_max_diff(s1.table.emb, t2.emb) <= tol
    assert _tree_max_diff((s1.backbone, s1.head),
                          jax.device_get((s2.backbone, s2.head))) <= tol
    assert abs(float(m1["loss"]) - float(m2["loss"])) <= tol


def test_aged_step_actually_changes_training(dataset):
    """Guards the plumbing end-to-end: with initialized stale rows of
    nonzero age, λ>0 must CHANGE the loss trajectory vs λ=0 (else the
    decay silently fell out somewhere between the flag and Eq. 1)."""
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    rng = jax.random.PRNGKey(3)
    var = G.VARIANTS["gst_efd"]
    base = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5))
    aged = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5,
                                     sed_decay=0.5))
    s1, s2 = state0, state0
    for _ in range(5):
        s1, m1 = base(s1, batch, rng)
        s2, m2 = aged(s2, batch, rng)
    assert float(m1["loss"]) != float(m2["loss"])


# ---------------------------------------------------------------------------
# RowForecaster round-trips
# ---------------------------------------------------------------------------


def test_forecast_never_observed_is_identity():
    f = RowForecaster(4, 2, 3)
    emb = np.random.default_rng(0).normal(size=(2, 2, 3)).astype(np.float32)
    age = np.zeros((2, 2), np.int32)
    init = np.ones((2, 2), bool)
    out = f.apply(np.array([0, 1]), emb, age, init, now_step=10)
    assert out is emb  # untouched buffer, not even a copy
    assert f.stats() == {"observed_rows": 0, "forecast_rows": 0}


def test_forecast_age_zero_is_identity():
    f = RowForecaster(4, 1, 3)
    rng = np.random.default_rng(1)
    old = rng.normal(size=(1, 1, 3)).astype(np.float32)
    f.observe(np.array([2]), old + 1.0, old,
              age_new=np.full((1, 1), 4, np.int32),
              age_old=np.zeros((1, 1), np.int32),
              init_new=np.ones((1, 1), bool), init_old=np.ones((1, 1), bool))
    emb = rng.normal(size=(1, 1, 3)).astype(np.float32)
    # row refreshed at step 10, asked for at step 10: age 0 < min_age
    out = f.apply(np.array([2]), emb,
                  np.full((1, 1), 10, np.int32), np.ones((1, 1), bool),
                  now_step=10)
    np.testing.assert_array_equal(out, emb)


def test_forecast_extrapolates_by_exact_velocity():
    f = RowForecaster(4, 1, 3)
    old = np.zeros((1, 1, 3), np.float32)
    # one residency: drifted +4.0 over 4 steps -> velocity exactly 1.0/step
    f.observe(np.array([0]), old + 4.0, old,
              age_new=np.full((1, 1), 4, np.int32),
              age_old=np.zeros((1, 1), np.int32),
              init_new=np.ones((1, 1), bool), init_old=np.ones((1, 1), bool))
    emb = np.full((1, 1, 3), 2.0, np.float32)
    # host copy last refreshed at step 4, asked for at step 10 -> age 6
    out = f.apply(np.array([0]), emb,
                  np.full((1, 1), 4, np.int32), np.ones((1, 1), bool),
                  now_step=10)
    np.testing.assert_array_equal(out, emb + 6.0)
    # uninitialized slots never extrapolate, whatever the velocity says
    out2 = f.apply(np.array([0]), emb,
                   np.full((1, 1), 4, np.int32), np.zeros((1, 1), bool),
                   now_step=10)
    np.testing.assert_array_equal(out2, emb)
    assert f.stats()["forecast_rows"] == 1


def test_forecast_ema_blends_observations():
    f = RowForecaster(2, 1, 1, alpha=0.5)
    z = np.zeros((1, 1, 1), np.float32)
    one_step = np.full((1, 1), 1, np.int32)
    for vel in (2.0, 6.0):  # EMA(0.5): 2.0 then 0.5*2 + 0.5*6 = 4.0
        f.observe(np.array([0]), z + vel, z, age_new=one_step,
                  age_old=np.zeros((1, 1), np.int32),
                  init_new=np.ones((1, 1), bool),
                  init_old=np.ones((1, 1), bool))
    out = f.apply(np.array([0]), z, np.zeros((1, 1), np.int32),
                  np.ones((1, 1), bool), now_step=1)
    np.testing.assert_array_equal(out, z + 4.0)


def test_store_forecast_without_step_hints_is_byte_identical():
    """--stale-forecast with no step hints (the serve replay path, and any
    driver that never passes step=) must leave the store byte-identical
    to the flag being off."""
    rng = np.random.default_rng(0)
    stores = [TieredStore(6, 2, 4, device_rows=2, stale_forecast=on)
              for on in (False, True)]
    try:
        tables = [s.init_device_table() for s in stores]
        schedule = [rng.integers(0, 6, 2) for _ in range(8)]
        for t, ids in enumerate(schedule):
            for i, s in enumerate(stores):
                tables[i], slots = s.prepare(tables[i], ids)
                # a deterministic "training write" so evictions carry
                # real deltas into the forecaster's observe stream
                tables[i] = tables[i]._replace(
                    emb=tables[i].emb.at[jnp.asarray(slots)].add(0.25 * t),
                    age=tables[i].age.at[jnp.asarray(slots)].set(t),
                    initialized=tables[i].initialized
                    .at[jnp.asarray(slots)].set(True))
        snaps = [s.snapshot(t) for s, t in zip(stores, tables)]
        assert _tree_bitwise(snaps[0], snaps[1])
        fstats = stores[1].stats()["forecast"]
        assert fstats["forecast_rows"] == 0  # never activated without hints
    finally:
        for s in stores:
            s.close()


# ---------------------------------------------------------------------------
# TRUE ages: refresh_ages and the stale-first victim
# ---------------------------------------------------------------------------


def _churn(refresh: bool):
    """Cap-2 stale-first store; row 1 is refreshed WHILE resident (its
    device age plane advances to 7), row 0 is not.  Returns the set of
    resident rows after a third row faults in."""
    store = TieredStore(3, 2, 4, device_rows=2, evict_policy="stale-first")
    try:
        table = store.init_device_table()
        table, _ = store.prepare(table, np.array([0, 1]), step=0)
        table, _ = store.prepare(table, np.array([0]), step=5)
        # training writes row 1 in place: device age plane advances, but
        # the SlotMap still scores it by its stale step-0 fault-in hint
        table = table._replace(
            age=table.age.at[store.resident_slot(1)].set(7))
        if refresh:
            store.refresh_ages(table)
        table, _ = store.prepare(table, np.array([2]))
        return {r for r in range(3) if store.resident_slot(r) is not None}
    finally:
        store.close()


def test_refresh_ages_protects_refreshed_resident_row():
    # with the readback, row 1 scores its TRUE age 7 and row 0 (device
    # plane still 0) is the victim
    assert _churn(refresh=True) == {1, 2}


def test_without_refresh_ages_refreshed_row_is_wrongly_evicted():
    # the counterfactual: stale hints make the freshly-refreshed row the
    # victim — the bug refresh_ages exists to fix
    assert _churn(refresh=False) == {0, 2}


def test_refresh_ages_noop_under_lru():
    store = TieredStore(3, 2, 4, device_rows=2, evict_policy="lru")
    try:
        table = store.init_device_table()
        table, _ = store.prepare(table, np.array([0, 1]))
        table = table._replace(
            age=table.age.at[store.resident_slot(0)].set(9))
        store.refresh_ages(table)  # must not touch LRU bookkeeping
        table, _ = store.prepare(table, np.array([2]))  # LRU victim: row 0
        assert store.resident_slot(0) is None
        assert store.resident_slot(1) is not None
    finally:
        store.close()


# ---------------------------------------------------------------------------
# SlotMap age bookkeeping under churn
# ---------------------------------------------------------------------------


def test_slotmap_stale_first_victim_order():
    m = SlotMap(2, policy="stale-first")
    m.reserve("a")
    m.set_age("a", 5)
    m.reserve("b")
    m.set_age("b", 3)
    slot, ev = m.reserve("c")          # b is stalest (3 < 5)
    assert ev[0] == "b" and slot == ev[1]
    slot, ev = m.reserve("d")          # c never reported -> stalest (-1)
    assert ev[0] == "c"
    assert sorted(k for k, _ in m.items()) == ["a", "d"]


def test_slotmap_age_dropped_with_eviction():
    m = SlotMap(1, policy="stale-first")
    m.reserve("a")
    m.set_age("a", 5)
    m.reserve("b")                     # evicts a
    assert m.age_of("a") is None
    m.set_age("a", 9)                  # not mapped: must stay a no-op
    assert m.age_of("a") is None
    # re-faulting "a" must not resurrect the pre-eviction age
    m.reserve("a")
    assert m.age_of("a") is None


def test_slotmap_pinned_keys_survive_stale_first():
    m = SlotMap(2, policy="stale-first")
    m.reserve("a")
    m.set_age("a", 0)                  # stalest reported
    m.reserve("b")
    m.set_age("b", 9)
    slot, ev = m.reserve("c", pinned={"a"})
    assert ev[0] == "b"                # pin overrides staleness order
    slot, ev = m.reserve("d", pinned={"a", "c"})
    assert (slot, ev) == (None, None)  # everything pinned: no victim


def test_slotmap_ties_break_by_coldness():
    m = SlotMap(2, policy="stale-first")
    m.reserve("a")
    m.reserve("b")
    m.set_age("a", 4)
    m.set_age("b", 4)
    m.touch("a")                       # b is now the colder of the tie
    slot, ev = m.reserve("c")
    assert ev[0] == "b"


# ---------------------------------------------------------------------------
# StalenessProbe: the effective-age metric family
# ---------------------------------------------------------------------------


def _probe_ages(step=100):
    rng = np.random.default_rng(0)
    age = (step - rng.integers(0, 60, (20, 4))).astype(np.int32)
    init = np.ones((20, 4), bool)
    return age, init, step


def test_probe_effective_age_absent_by_default():
    reg = MetricsRegistry()
    age, init, step = _probe_ages()
    out = StalenessProbe(registry=reg).observe_ages(age, init, step)
    assert "effective_age_steps" not in out
    assert "staleness.effective_age" not in reg.snapshot()


def test_probe_effective_age_below_row_age_under_decay():
    reg = MetricsRegistry()
    age, init, step = _probe_ages()
    out = StalenessProbe(registry=reg, sed_decay=0.1).observe_ages(
        age, init, step)
    eff, raw = out["effective_age_steps"], out["row_age_steps"]
    # age·e^{-λ·age} < age pointwise for age>0 ⇒ every order statistic
    # shrinks — the invariant the CI gate leg asserts on real runs
    assert raw["p99"] > 0
    for q in ("p50", "p99", "max"):
        assert eff[q] < raw[q]
    assert "staleness.effective_age" in reg.snapshot()


def test_probe_forecast_zeroes_eligible_slots():
    reg = MetricsRegistry()
    age, init, step = _probe_ages()
    age = np.minimum(age, step - 1)   # every slot at least 1 step old
    out = StalenessProbe(registry=reg, forecast=True).observe_ages(
        age, init, step)
    assert out["effective_age_steps"]["max"] == 0.0
