"""Memory observability (src/repro/obs/memory.py + gate) — ISSUE 8 contract.

  * the probe captures compiled memory/cost stats once per (site, shape
    signature) and only counts calls afterwards;
  * measure-on-the-side: the traced jaxpr of the gst_efd train step is
    bit-identical with the probe installed or not, and the probed wrapper
    returns exactly what the raw jitted callable returns;
  * the streaming encoder's compiled temp bytes are chunk-count-
    independent and >= the jaxpr-walk max_intermediate_bytes bound (the
    serve-side constant-memory claim, measured not argued);
  * Chrome-trace "C" counter events interleaved with spans from multiple
    threads export as a valid monotonic trace, and the validator rejects
    malformed counter events;
  * the tiered store's host-tier byte gauge equals snapshot() nbytes;
  * when memory_analysis is unavailable the probe degrades to the
    accounting-only mode instead of raising;
  * the memory gate passes on flat GST temp and fails when the sweep
    shows growth (and when the full-graph control stops growing);
  * bench_diff joins merge-keyed BENCH files and reports numeric drift;
  * Obs --mem-probe writes the per-site memory event ahead of the final
    summary record and restores the global probe on close.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gst as G
from repro.dist import pipeline as DP
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.kernels.ops import max_intermediate_bytes
from repro.obs import (MemoryProbe, MetricsRegistry, NullProbe, Obs,
                       get_probe, get_registry, null_probe, null_registry,
                       null_tracer, probe_jit, set_probe, set_registry,
                       set_tracer, shape_signature, tree_nbytes,
                       validate_chrome_trace)
from repro.obs.gate import GateFailure, check_memory_json
from repro.obs.trace import Tracer
from repro.optim import make_optimizer
from repro.roofline.analysis import (compiled_memory_stats,
                                     device_peak_bytes)
from repro.serve.engine import graph_to_chunks, make_stream_encoder
from repro.serve.buckets import default_ladder
from repro.store import TieredStore

HID = 8


@pytest.fixture(scope="module")
def dataset():
    graphs = D.make_malnet_like(n_graphs=16, seed=0)
    ds, _ = DP.segment_dataset_shared(graphs, 16, seed=0)
    return ds


@pytest.fixture(autouse=True)
def _clean_globals():
    """Every test starts and ends with the null registry/tracer/probe
    installed (the process defaults) — no cross-test telemetry bleed."""
    set_registry(null_registry())
    set_tracer(null_tracer())
    set_probe(null_probe())
    yield
    set_registry(null_registry())
    set_tracer(null_tracer())
    set_probe(null_probe())


def _state(ds):
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    from repro.core import embedding_table as tbl
    return enc, opt, G.TrainState(bb, head, opt.init((bb, head)),
                                  tbl.init_table(ds.n, ds.j_max, HID),
                                  jnp.zeros((), jnp.int32))


def _batch(ds, ids):
    return jax.tree_util.tree_map(jnp.asarray, DP._assemble(ds, ids))


# ---------------------------------------------------------------------------
# capture + dedup
# ---------------------------------------------------------------------------


def test_probe_capture_keyed_by_shape_signature():
    probe = MemoryProbe()
    set_probe(probe)
    reg = MetricsRegistry()
    set_registry(reg)
    f = probe_jit("t.add", jax.jit(lambda a, b: a + b))

    x4, x8 = jnp.ones((4,)), jnp.ones((8,))
    f(x4, x4)
    f(x4, x4)          # same signature: counted, not re-measured
    f(x8, x8)          # new signature: second record
    recs = {(r["site"], r["signature"]): r for r in probe.records()}
    assert len(recs) == 2
    sig4 = shape_signature(((x4, x4), {}))
    assert recs[("t.add", sig4)]["calls"] == 2
    r = recs[("t.add", sig4)]
    assert r["mode"] == "compiled"
    assert r["peak_bytes"] > 0 and r["temp_bytes"] >= 0
    assert r["cost"] is not None and r["cost"]["flops"] >= 0
    # gauges landed in the registry under the site name
    snap = reg.snapshot()
    assert snap["mem.device.peak_bytes.t.add"]["value"] > 0
    assert "mem.device.temp_bytes.t.add" in snap


def test_signature_distinguishes_dtype_and_shape():
    a = jnp.ones((2, 3), jnp.float32)
    b = jnp.ones((2, 3), jnp.int32)
    assert shape_signature(a) != shape_signature(b)
    assert shape_signature(a) != shape_signature(jnp.ones((3, 2)))
    assert shape_signature({"x": a}) == shape_signature({"x": a})


def test_tree_nbytes_counts_numpy_and_jax_leaves():
    host = {"x": np.zeros((4, 2), np.float32), "i": np.zeros((4,), np.int64)}
    assert tree_nbytes(host) == 4 * 2 * 4 + 4 * 8
    assert tree_nbytes(jnp.zeros((8,), jnp.float32)) == 32


def test_null_probe_and_passthrough():
    assert not NullProbe().enabled
    assert get_probe() is null_probe()
    jitted = jax.jit(lambda x: x * 2)
    f = probe_jit("t.mul", jitted)
    # attribute passthrough: AOT entry points still reachable
    assert f.lower(jnp.ones((2,))).compile() is not None
    # disabled probe records nothing
    f(jnp.ones((2,)))
    assert get_probe().records() == []


# ---------------------------------------------------------------------------
# measure-on-the-side: jaxpr identity + result identity
# ---------------------------------------------------------------------------


def test_train_step_jaxpr_identical_with_probe_installed(dataset):
    ds = dataset
    enc, opt, state = _state(ds)
    step_fn = G.make_train_step(enc, opt, G.VARIANTS["gst_efd"],
                                keep_prob=0.5)
    batch = _batch(ds, np.arange(4, dtype=np.int64))
    rng = jax.random.PRNGKey(0)

    baseline = str(jax.make_jaxpr(step_fn)(state, batch, rng))
    obs = Obs(mem_probe=True, install=True)
    try:
        assert get_probe() is obs.probe and get_probe().enabled
        probed = probe_jit("train.step", jax.jit(step_fn))
        _, m = probed(state, batch, rng)
        jax.block_until_ready(m["loss"])
        instrumented = str(jax.make_jaxpr(step_fn)(state, batch, rng))
        assert [r["site"] for r in obs.probe.records()] == ["train.step"]
    finally:
        obs.uninstall()
    assert instrumented == baseline


def test_probed_results_identical_to_raw(dataset):
    ds = dataset
    enc, opt, state = _state(ds)
    step = jax.jit(G.make_eval_step(enc))
    batch = _batch(ds, np.arange(4, dtype=np.int64))
    raw = step(state, batch)
    set_probe(MemoryProbe())
    probed = probe_jit("t.eval", step)(state, batch)
    np.testing.assert_array_equal(np.asarray(raw["loss"]),
                                  np.asarray(probed["loss"]))


# ---------------------------------------------------------------------------
# streaming constant-memory claim, measured
# ---------------------------------------------------------------------------


def test_streaming_temp_flat_across_chunk_counts_and_bounded():
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=HID)
    bb = gnn_init(jax.random.key(0), cfg)
    head = G.head_init(jax.random.key(1), HID, 5, "mlp")
    g = D.make_malnet_like(n_graphs=1, seed=0)[0]
    spec = default_ladder(16)[-1]
    base = graph_to_chunks(g, spec, 2, partition_max_nodes=16)
    stream = make_stream_encoder(cfg)

    temps, bounds = [], []
    chunks = base
    for _ in range(3):           # C, 2C, 4C chunks of identical shape
        dev = {k: jnp.asarray(v) for k, v in chunks.items()}
        mem = compiled_memory_stats(
            stream.lower(bb, head, dev).compile())
        if mem is None:
            pytest.skip("memory_analysis unavailable on this backend")
        temps.append(mem["temp_size_in_bytes"])
        bounds.append(int(max_intermediate_bytes(stream, bb, head, dev)))
        chunks = {k: np.concatenate([v, v]) for k, v in chunks.items()}

    assert len(set(temps)) == 1, f"stream temp grew with chunks: {temps}"
    assert all(t >= b for t, b in zip(temps, bounds)), (temps, bounds)
    assert len(set(bounds)) == 1   # the accounting bound is flat too


def test_device_peak_model_consistent():
    mem = {"argument_size_in_bytes": 100, "output_size_in_bytes": 40,
           "temp_size_in_bytes": 10, "alias_size_in_bytes": 30}
    assert device_peak_bytes(mem) == 120
    assert device_peak_bytes({}) == 0


# ---------------------------------------------------------------------------
# counter events in the trace
# ---------------------------------------------------------------------------


def test_counter_and_span_interleave_exports_valid_trace(tmp_path):
    tr = Tracer()
    set_tracer(tr)
    gate = threading.Barrier(3)

    def worker():
        gate.wait()
        for i in range(20):
            with tr.span("w.step", i=i):
                tr.counter("mem.bytes", staged=float(i * 100))

    threads = [threading.Thread(target=worker) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        payload = json.load(f)
    assert validate_chrome_trace(payload) == []
    phases = {ev["ph"] for ev in payload["traceEvents"]}
    assert "C" in phases and "X" in phases


def test_validator_rejects_malformed_counter_events():
    base = {"name": "c", "ph": "C", "ts": 1, "pid": 1, "tid": 1}
    ok = {**base, "args": {"bytes": 42.0}}
    assert validate_chrome_trace({"traceEvents": [ok]}) == []
    no_args = dict(base)
    assert validate_chrome_trace({"traceEvents": [no_args]})
    empty = {**base, "args": {}}
    assert validate_chrome_trace({"traceEvents": [empty]})
    non_numeric = {**base, "args": {"bytes": "lots"}}
    assert validate_chrome_trace({"traceEvents": [non_numeric]})
    boolean = {**base, "args": {"bytes": True}}
    assert validate_chrome_trace({"traceEvents": [boolean]})


def test_counter_requires_numeric_series():
    tr = Tracer()
    with pytest.raises(ValueError):
        tr.counter("c", label="not-a-number")
    tr.counter("c", a=1, label="ignored")   # numeric subset recorded
    (ev,) = tr.events()
    assert ev["args"] == {"a": 1.0}


# ---------------------------------------------------------------------------
# host-side tracking
# ---------------------------------------------------------------------------


def test_host_tier_gauge_matches_snapshot_nbytes(dataset):
    ds = dataset
    probe = MemoryProbe()
    set_probe(probe)
    set_registry(MetricsRegistry())
    store = TieredStore(ds.n, ds.j_max, HID, device_rows=4)
    try:
        table = store.init_device_table()
        table, _ = store.prepare(table, np.arange(4, dtype=np.int64))
        store.publish_counters()
        snap = store.snapshot(table)
        want = sum(int(np.asarray(x).nbytes) for x in snap)
        assert probe.host_bytes()["store.host_tier"] == want
        assert store.host_tier_bytes() == want
        reg_snap = get_registry().snapshot()
        assert reg_snap["mem.host.store.host_tier_bytes"]["value"] == want
    finally:
        store.close()


def test_feeder_staging_bytes_published(dataset):
    ds = dataset
    probe = MemoryProbe()
    set_probe(probe)
    set_registry(MetricsRegistry())
    sched = [np.arange(4, dtype=np.int64)]
    feeder = DP.SyncSegmentFeeder(ds, sched, lambda h: h)
    batches = list(feeder)
    assert len(batches) == 1
    assert probe.host_bytes()["feeder.staging"] == tree_nbytes(batches[0])


# ---------------------------------------------------------------------------
# accounting-only degrade (no memory_analysis on the backend)
# ---------------------------------------------------------------------------


class _NoMemCompiled:
    def memory_analysis(self):
        return None

    def cost_analysis(self):
        return {"flops": 3.0, "bytes accessed": 7.0}


class _NoMemLowered:
    def compile(self):
        return _NoMemCompiled()


class _NoMemJit:
    def lower(self, *args, **kwargs):
        return _NoMemLowered()

    def __call__(self, *args, **kwargs):
        return args


def test_probe_degrades_to_accounting_without_memory_analysis():
    probe = MemoryProbe(accounting_fallback=False)
    set_probe(probe)
    f = probe_jit("t.nomem", _NoMemJit())
    f(jnp.ones((2,)))
    (rec,) = probe.records()
    assert rec["mode"] == "accounting"
    assert "peak_bytes" not in rec          # nothing fabricated
    assert rec["cost"] == {"flops": 3.0, "bytes_accessed": 7.0}


def test_probe_survives_uncompilable_entry_point():
    class _Boom:
        def lower(self, *a, **k):
            raise RuntimeError("no lowering for you")

        def __call__(self, *a, **k):
            return 42

    probe = MemoryProbe()
    set_probe(probe)
    assert probe_jit("t.boom", _Boom())() == 42   # the call still runs
    (rec,) = probe.records()
    assert rec["mode"] == "error"


# ---------------------------------------------------------------------------
# the memory gate
# ---------------------------------------------------------------------------


def _mem_payload(gst=1.05, full=5.0, stream=1.0, bound_ok=True,
                 ladder=800_000):
    return {"benchmark": "gst_memory", "unit": "bytes", "runs": {
        "k=1": {"summary": {
            "gst_temp_ratio_max_over_min": gst,
            "full_temp_ratio_max_over_min": full,
            "streaming_temp_ratio_max_over_min": stream,
            "streaming_bound_ok": bound_ok,
            "ladder_total_peak_bytes": ladder,
        }}}}


def _write(tmp_path, payload, name="mem.json"):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def test_memory_gate_passes_on_flat_gst(tmp_path):
    path = _write(tmp_path, _mem_payload())
    lines = check_memory_json(path, mem_epsilon=0.25, stream_epsilon=0.01,
                              growth_floor=2.0, ladder_budget=1_000_000)
    assert len(lines) == 1 and "flat" in lines[0]


@pytest.mark.parametrize("payload,msg", [
    (_mem_payload(gst=1.5), "constant-memory claim"),
    (_mem_payload(full=1.2), "vacuous"),
    (_mem_payload(stream=1.3), "chunk"),
    (_mem_payload(bound_ok=False), "bound"),
    (_mem_payload(ladder=2_000_000), "budget"),
])
def test_memory_gate_fails_on_each_violation(tmp_path, payload, msg):
    path = _write(tmp_path, payload)
    with pytest.raises(GateFailure, match=msg):
        check_memory_json(path, mem_epsilon=0.25, stream_epsilon=0.01,
                          growth_floor=2.0, ladder_budget=1_000_000)


def test_memory_gate_rejects_wrong_file_kind(tmp_path):
    path = _write(tmp_path, {"benchmark": "gst_step", "runs": {}})
    with pytest.raises(GateFailure, match="not a gst_memory"):
        check_memory_json(path, mem_epsilon=0.25, stream_epsilon=0.01,
                          growth_floor=2.0, ladder_budget=None)


# ---------------------------------------------------------------------------
# bench_diff
# ---------------------------------------------------------------------------


def test_bench_diff_reports_numeric_drift(tmp_path):
    from repro.obs.bench_diff import diff_files
    base = {"benchmark": "gst_memory", "runs": {
        "k=1": {"summary": {"a": 100, "nested": [{"b": 2.0}]},
                "config": {"hidden": 32}}}}
    fresh = json.loads(json.dumps(base))
    fresh["runs"]["k=1"]["summary"]["a"] = 140          # +40%
    fresh["runs"]["k=1"]["summary"]["new_leaf"] = 1
    report = diff_files(_write(tmp_path, fresh, "fresh.json"),
                        _write(tmp_path, base, "base.json"),
                        tolerance=0.25)
    (item,) = report["common"]
    by_metric = {d["metric"]: d for d in item["drift"]}
    assert by_metric["summary.a"]["rel_delta"] == pytest.approx(0.4)
    assert by_metric["summary.new_leaf"]["note"] == "missing in baseline"
    assert "config.hidden" not in by_metric        # config never diffed


def test_bench_diff_disjoint_keys_not_fatal(tmp_path):
    from repro.obs.bench_diff import diff_files
    a = {"benchmark": "gst_memory", "runs": {"k=1": {"summary": {"a": 1}}}}
    b = {"benchmark": "gst_memory", "runs": {"k=2": {"summary": {"a": 1}}}}
    report = diff_files(_write(tmp_path, a, "a.json"),
                        _write(tmp_path, b, "b.json"), tolerance=0.25)
    assert report["common"] == []
    assert report["only_fresh"] == ["k=1"]
    assert report["only_baseline"] == ["k=2"]


# ---------------------------------------------------------------------------
# Obs lifecycle
# ---------------------------------------------------------------------------


def test_obs_mem_probe_writes_memory_event_before_summary(tmp_path):
    out = str(tmp_path / "obs.jsonl")
    obs = Obs(mem_probe=True, metrics_out=out)
    assert get_probe() is obs.probe
    f = probe_jit("t.sq", jax.jit(lambda x: x * x))
    f(jnp.ones((4,)))
    obs.close()
    assert get_probe() is null_probe()     # global restored
    with open(out) as fh:
        records = [json.loads(line) for line in fh]
    assert records[-1]["type"] == "summary"
    (mem_ev,) = [r for r in records if r.get("event") == "memory"]
    assert [r["site"] for r in mem_ev["records"]] == ["t.sq"]
    assert mem_ev["records"][0]["mode"] == "compiled"
    assert "mem.device.peak_bytes.t.sq" in records[-1]["metrics"]
