"""Optimizer + checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, make_optimizer)


def test_adamw_converges_on_quadratic():
    w = jnp.asarray([5.0, -3.0])
    opt = make_optimizer("adamw", lr=0.1, weight_decay=0.0)
    state = opt.init(w)
    for _ in range(200):
        grads = 2 * w
        w, state, _ = opt.update(w, grads, state)
    np.testing.assert_allclose(np.asarray(w), 0.0, atol=1e-2)


def test_weight_decay_shrinks_weights():
    w = jnp.asarray([1.0])
    opt_wd = make_optimizer("adamw", lr=0.01, weight_decay=0.5)
    opt_no = make_optimizer("adam", lr=0.01, weight_decay=0.5)  # adam ignores wd
    s1, s2 = opt_wd.init(w), opt_no.init(w)
    g = jnp.asarray([0.0])
    w1, _, _ = opt_wd.update(w, g, s1)
    w2, _, _ = opt_no.update(w, g, s2)
    assert float(w1[0]) < float(w[0])
    np.testing.assert_allclose(float(w2[0]), 1.0, atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 100, warmup=10)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(10)), 1.0, atol=1e-6)
    assert float(s(55)) < 1.0
    np.testing.assert_allclose(float(s(100)), 0.0, atol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2], jnp.int32)},
            "lst": [jnp.ones((2,)), jnp.zeros((1,), jnp.bool_)]}
    path = save_checkpoint(str(tmp_path), 3, tree)
    assert latest_checkpoint(str(tmp_path)) == path
    restored = load_checkpoint(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for step in range(6):
        save_checkpoint(str(tmp_path), step, tree, keep=3)
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 3
    assert files[-1] == "step_00000005.ckpt"
