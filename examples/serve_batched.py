"""Batched serving example: prefill + decode across architecture families.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model


def serve_one(arch: str, batch=2, prompt=8, gen=8):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    total = prompt + gen
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt)), jnp.int32)
    caches = model.init_cache(batch, total, jnp.float32)
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        frames = jnp.asarray(rng.normal(size=(batch, cfg.encoder_seq_len,
                                               cfg.d_model)), jnp.float32)
        caches = {"self": caches,
                  "cross": encdec.cross_kv(params, cfg,
                                           encdec.encode(params, cfg, frames))}
    decode = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))
    cur = toks[:, :1]
    out = []
    t0 = time.time()
    for t in range(total - 1):
        logits, caches = decode(params, cur, caches,
                                jnp.full((batch,), t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        cur = toks[:, t + 1:t + 2] if t + 1 < prompt else nxt
        if t + 1 >= prompt:
            out.append(np.asarray(nxt[:, 0]))
    dt = (time.time() - t0) / (total - 1) * 1e3
    print(f"{arch:22s} generated {len(out)} tokens/seq x{batch} "
          f"({dt:.0f} ms/step incl. compile)")
    return np.stack(out, 1)


def main():
    for arch in ["olmo-1b", "zamba2-1.2b", "rwkv6-7b", "deepseek-v3-671b",
                 "whisper-large-v3"]:
        serve_one(arch)


if __name__ == "__main__":
    main()
    sys.exit(0)
