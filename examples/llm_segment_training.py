"""End-to-end driver (deliverable b): train a ~100M-param assigned
architecture for a few hundred steps with GST+EFD on the sequence track.

The backbone is internlm2-1.8b's family scaled to ~100M params (8 layers,
d_model=512 — same code path as the full config); documents are 4-segment
token sequences whose property (majority topic) needs whole-input evidence.

    PYTHONPATH=src python examples/llm_segment_training.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.data.tokens import doc_batch_iterator, make_property_docs
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer


def main(steps: int = 300):
    base = get_config("internlm2-1.8b")
    cfg = dataclasses.replace(
        base, num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        d_ff=1536, vocab_size=2048, head_dim=64, gst_num_segments=4,
        gst_num_classes=5)
    model = build_model(cfg)
    n_params = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model.init(jax.random.key(0)))))
    print(f"backbone: {cfg.name} family, {n_params/1e6:.0f}M params")

    J, L = 4, 128
    n_docs = 256
    docs = make_property_docs(n_docs=n_docs, n_segments=J, seg_len=L,
                              vocab=cfg.vocab_size, n_topics=5, seed=0)
    params = model.init(jax.random.key(0))
    head = G.head_init(jax.random.key(1), cfg.d_model, 5, "mlp")
    opt = make_optimizer("adamw", lr=3e-4, weight_decay=0.01,
                         schedule=cosine_schedule(3e-4, steps, warmup=20))
    state = G.TrainState(params, head, opt.init((params, head)),
                         init_table(n_docs, J, cfg.d_model),
                         jnp.zeros((), jnp.int32))
    step = jax.jit(G.make_train_step(
        lambda p, s: model.encode_segment(p, s), opt, G.VARIANTS["gst_efd"],
        keep_prob=0.5))

    rng = np.random.default_rng(0)
    it, t0 = 0, time.time()
    accs = []
    while it < steps:
        for tup in doc_batch_iterator(docs, 16, rng=rng):
            batch = G.GSTBatch({"tokens": jnp.asarray(tup[0]["tokens"])},
                               jnp.asarray(tup[1]), jnp.asarray(tup[2]),
                               jnp.asarray(tup[3]))
            state, m = step(state, batch, jax.random.key(it))
            accs.append(float(m["metric"]))
            it += 1
            if it % 25 == 0:
                print(f"step {it:4d}: loss={float(m['loss']):.3f} "
                      f"acc(25)={np.mean(accs[-25:]):.3f} "
                      f"({(time.time()-t0)/it*1e3:.0f} ms/step)", flush=True)
            if it >= steps:
                break
    final = np.mean(accs[-50:])
    print(f"final train accuracy (last 50 steps): {final:.3f} (chance 0.2)")
    return final


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    sys.exit(0 if main(args.steps) > 0.3 else 1)
