"""TpuGraphs-style ranking with GST (paper §5.3): per-segment runtime
prediction + sum pooling (the head is part of F; F' = Σ), PairwiseHinge
loss, OPA metric.

    PYTHONPATH=src python examples/tpugraphs_ranking.py
"""
import sys

from repro.graphs.experiment import run_experiment


def main():
    print("variant      train_OPA  test_OPA  ms/iter")
    results = {}
    for variant in ["gst", "gst_one", "gst_e", "gst_efd"]:
        r = run_experiment(dataset="tpugraphs", backbone="sage",
                           variant=variant, n_graphs=64, epochs=25,
                           finetune_epochs=0, seed=0)
        results[variant] = r
        print(f"{variant:12s} {r.train_metric:8.3f} {r.test_metric:9.3f} "
              f"{r.ms_per_iter:7.1f}")
    # the paper's Table 2 ordering: GST fits train best; E-variants are
    # faster per iteration than GST
    assert results["gst"].ms_per_iter > results["gst_e"].ms_per_iter
    return results


if __name__ == "__main__":
    main()
    sys.exit(0)
