"""Quickstart: GST+EFD on a synthetic MalNet-like dataset in ~2 minutes (CPU).

    PYTHONPATH=src python examples/quickstart.py

Walks the full pipeline: generate graphs -> partition (METIS-like BFS) ->
padded segment batches -> GST+EFD training (sampled-segment backprop +
historical embedding table + SED) -> prediction-head finetuning -> eval.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.graphs import batching as Bt
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.optim import make_optimizer


def main():
    # 1. data + preprocessing (paper §3.1: partition once, up front)
    graphs = D.make_malnet_like(n_graphs=80, seed=0)
    train, test = graphs[:64], graphs[64:]
    ds = Bt.segment_dataset(train, max_seg_nodes=64, method="bfs")
    ds_test = Bt.segment_dataset(test, max_seg_nodes=64, method="bfs",
                                 j_max=ds.j_max, e_max=ds.e_max)
    print(f"{ds.n} train graphs, J_max={ds.j_max} segments of <= {ds.m_max} nodes")

    # 2. model: SAGE backbone F + MLP head F'
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=64)
    encode = make_encode_fn(cfg)
    backbone = gnn_init(jax.random.key(0), cfg)
    head = G.head_init(jax.random.key(1), 64, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    state = G.TrainState(backbone, head, opt.init((backbone, head)),
                         init_table(ds.n, ds.j_max, 64), jnp.zeros((), jnp.int32))

    # 3. GST+EFD training (Algorithm 2)
    step = jax.jit(G.make_train_step(encode, opt, G.VARIANTS["gst_efd"],
                                     keep_prob=0.5))
    eval_step = jax.jit(G.make_eval_step(encode))
    refresh = jax.jit(G.make_refresh_step(encode))
    rng = np.random.default_rng(0)

    def batches(d, shuffle=True):
        for tup in Bt.batch_iterator(d, 8, rng=rng, shuffle=shuffle):
            yield G.GSTBatch({k: jnp.asarray(v) for k, v in tup[0].items()},
                             jnp.asarray(tup[1]), jnp.asarray(tup[2]),
                             jnp.asarray(tup[3]))

    for epoch in range(30):
        for batch in batches(ds):
            state, m = step(state, batch, jax.random.key(epoch))
        if (epoch + 1) % 10 == 0:
            print(f"epoch {epoch+1}: loss={float(m['loss']):.3f} "
                  f"train_acc={float(m['metric']):.3f}")

    # 4. head finetuning (paper §3.3): refresh table, train F' only
    for batch in batches(ds, shuffle=False):
        state = refresh(state, batch)
    ft_opt = make_optimizer("adam", lr=2e-3)
    state = state._replace(opt_state=ft_opt.init(state.head))
    ft = jax.jit(G.make_finetune_step(ft_opt))
    for _ in range(10):
        for batch in batches(ds):
            state, m = ft(state, batch)
    state = state._replace(opt_state=opt.init((state.backbone, state.head)))

    # 5. eval (all segments fresh — the paper's test distribution)
    accs = [float(eval_step(state, b)["metric"]) for b in batches(ds_test, False)]
    print(f"test accuracy: {np.mean(accs):.3f} (chance = 0.2)")
    return np.mean(accs)


if __name__ == "__main__":
    acc = main()
    sys.exit(0 if acc > 0.3 else 1)
